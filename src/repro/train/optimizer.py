"""AdamW with mixed precision and ZeRO-1 state sharding (from scratch).

Params live in the compute dtype (bf16 on the fleet); the optimizer holds
fp32 master params + first/second moments. Under a ShardingPlan the
optimizer state is additionally sharded over the data axes (ZeRO-1): for
each leaf the first dimension that is unsharded and divisible picks up
the data axes — see :func:`zero_specs`.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from ..parallel import ShardingPlan, param_specs


class AdamWState(NamedTuple):
    step: jax.Array
    master: Any      # fp32 params
    mu: Any
    nu: Any


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def cosine_schedule(base_lr: float, warmup: int, total: int,
                    final_frac: float = 0.1) -> Callable:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, base_lr * cos)
    return lr


@dataclasses.dataclass
class AdamW:
    lr: Callable | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0

    def init(self, params) -> AdamWState:
        def f32(p):
            return jax.tree.map(lambda x: x.astype(jnp.float32), p)
        zeros = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), params)
        return AdamWState(step=jnp.zeros((), jnp.int32), master=f32(params),
                          mu=zeros,
                          nu=jax.tree.map(jnp.zeros_like, zeros))

    def update(self, params, grads, state: AdamWState):
        """Returns (new_params_in_compute_dtype, new_state, stats)."""
        step = state.step + 1
        gnorm = global_norm(grads)
        scale = jnp.float32(1.0)
        if self.clip_norm is not None:
            scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-9))
        lr = self.lr(step) if callable(self.lr) else self.lr
        b1, b2 = self.b1, self.b2
        c1 = 1 - b1 ** step.astype(jnp.float32)
        c2 = 1 - b2 ** step.astype(jnp.float32)

        gs = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)
        mu = jax.tree.map(lambda g, m: b1 * m + (1 - b1) * g, gs, state.mu)
        nu = jax.tree.map(lambda g, v: b2 * v + (1 - b2) * g * g, gs, state.nu)
        master = jax.tree.map(
            lambda m, v, mp: mp - lr * ((m / c1) / (jnp.sqrt(v / c2) + self.eps)
                                        + self.weight_decay * mp),
            mu, nu, state.master)
        new_params = jax.tree.map(
            lambda mp, p: mp.astype(p.dtype), master, params)
        return new_params, AdamWState(step, master, mu, nu), {
            "grad_norm": gnorm, "lr": jnp.asarray(lr, jnp.float32)}


# ---------------------------------------------------------------------------
# ZeRO-1 sharding for the optimizer state
# ---------------------------------------------------------------------------
def zero_specs(plan: ShardingPlan, params) -> Any:
    """Optimizer-state NamedShardings: param spec + data axes on one dim.

    For each leaf, take the parameter's spec and add the plan's data axes
    to the first dimension that is currently unsharded and divisible —
    classic ZeRO-1 so fp32 master/mu/nu are split across data replicas.
    """
    base = param_specs(plan, params)
    data_axes = plan.data_axes
    if data_axes is None:
        return base
    dsize = plan.axis_size(data_axes)

    def one(leaf, sh: NamedSharding):
        dims = list(sh.spec) + [None] * (leaf.ndim - len(sh.spec))
        flat = set()
        for d in dims:
            for a in ((d,) if isinstance(d, str) else (d or ())):
                flat.add(a)
        if not flat.intersection(data_axes):   # already FSDP-sharded: done
            for d in range(leaf.ndim):
                if dims[d] is None and leaf.shape[d] % dsize == 0 and \
                        leaf.shape[d] >= dsize:
                    dims[d] = (data_axes if len(data_axes) > 1
                               else data_axes[0])
                    break
        return NamedSharding(plan.mesh, P(*dims))

    return jax.tree.map(one, params, base)


def opt_state_specs(plan: ShardingPlan, params,
                    state_like: AdamWState) -> AdamWState:
    z = zero_specs(plan, params)
    scalar = NamedSharding(plan.mesh, P())
    del state_like
    return AdamWState(step=scalar, master=z, mu=z, nu=z)
