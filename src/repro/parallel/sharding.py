"""Logical-axis sharding: one rules table, every arch, both meshes.

Scheme (MaxText-style logical axes):

* every parameter leaf name maps to a tuple of LOGICAL axis names
  (``LEAF_AXES``); leading stack dims (scan-over-layers) are implicit.
* a :class:`ShardingPlan` maps logical names -> mesh axes for one
  (mesh x arch x shape); :func:`make_plan` builds the baseline plan and
  hillclimb overrides mutate ``rules``.
* model code never sees the mesh: it calls :func:`shard` with logical
  names, resolved against the *active* plan (a module global set by the
  step builders). With no active plan the call is a no-op, so single-
  device smoke tests run the same code.

Baseline distribution:
  batch  -> all data-like mesh axes ('pod','data')   [DP]
  q_dim / kv_dim / ff / vocab / experts / ssm_inner -> 'model'  [TP/EP]
  seq    -> 'model' for train (sequence-parallel residuals), 'data' for
            batch-1 long-context decode
  cache_seq -> 'model' when kv heads don't divide the model axis
            (flash-decode style cache split), else kv sharded.
"""
from __future__ import annotations

import contextlib
import dataclasses
import re
from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from ..configs import ModelConfig, ShapeSpec

# ---------------------------------------------------------------------------
# Leaf name -> logical axes (per trailing dim; leading stack dims implicit)
# ---------------------------------------------------------------------------
LEAF_AXES: dict[str, tuple[Optional[str], ...]] = {
    # embeddings / head
    "embed": ("vocab", "w_emb"),
    "head": ("w_emb", "vocab"),
    "pos_embed": ("seq_const", "w_emb"),
    # attention
    "wq": ("w_emb", "q_dim"),
    "wk": ("w_emb", "kv_dim"),
    "wv": ("w_emb", "kv_dim"),
    "wo": ("q_dim", "w_emb"),
    "q_norm": ("head_dim",),
    "k_norm": ("head_dim",),
    # dense mlp
    "w_gate": ("w_emb", "ff"),
    "w_up": ("w_emb", "ff"),
    "w_down": ("ff", "w_emb"),
    # MoE
    "router": ("w_emb", "experts_r"),
    "moe_gate": ("experts", "w_emb", "ff"),
    "moe_up": ("experts", "w_emb", "ff"),
    "moe_down": ("experts", "ff", "w_emb"),
    "sh_gate": ("w_emb", "sh_ff"),
    "sh_up": ("w_emb", "sh_ff"),
    "sh_down": ("sh_ff", "w_emb"),
    # mamba2
    "wz": ("w_emb", "ssm_inner"),
    "wx": ("w_emb", "ssm_inner"),
    "wB": ("w_emb", "gn"),
    "wC": ("w_emb", "gn"),
    "wdt": ("w_emb", "nh"),
    "dt_bias": ("nh",),
    "A_log": ("nh",),
    "D": ("nh",),
    "conv_w": ("conv_k", "conv_c"),
    "out_proj": ("ssm_inner", "w_emb"),
    # norms
    "ln1": ("w_emb",), "ln2": ("w_emb",), "ln3": ("w_emb",),
    "norm": ("w_emb",), "final_norm": ("w_emb",),
}


@dataclasses.dataclass
class ShardingPlan:
    mesh: Mesh
    rules: dict[str, Any]          # logical axis -> mesh axis (str/tuple/None)
    cfg: ModelConfig
    shape: ShapeSpec

    @property
    def data_axes(self):
        return self.rules["batch"]

    def spec(self, *logical: Optional[str]) -> P:
        """PartitionSpec for a tuple of per-dim logical names."""
        return P(*[self.rules.get(a) if a else None for a in logical])

    def named(self, *logical: Optional[str]) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(*logical))

    def axis_size(self, mesh_axes) -> int:
        if mesh_axes is None:
            return 1
        if isinstance(mesh_axes, str):
            mesh_axes = (mesh_axes,)
        return int(np.prod([self.mesh.shape[a] for a in mesh_axes]))


# ---------------------------------------------------------------------------
# Active-plan global (set by step builders, read by model code)
# ---------------------------------------------------------------------------
_ACTIVE: list[Optional[ShardingPlan]] = [None]


def active_plan() -> Optional[ShardingPlan]:
    return _ACTIVE[0]


@contextlib.contextmanager
def activate(plan: Optional[ShardingPlan]):
    prev = _ACTIVE[0]
    _ACTIVE[0] = plan
    try:
        yield plan
    finally:
        _ACTIVE[0] = prev


def shard(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Constrain ``x``'s sharding by logical axis names (no-op w/o a plan).

    Skips any axis whose extent doesn't divide the mesh axes product —
    keeps one code path valid for smoke shapes and full shapes alike.
    """
    plan = _ACTIVE[0]
    if plan is None:
        return x
    dims = []
    for d, name in enumerate(logical):
        axes = plan.rules.get(name) if name else None
        if axes is not None and x.shape[d] % plan.axis_size(axes) != 0:
            axes = None
        dims.append(axes)
    if all(a is None for a in dims):
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(plan.mesh, P(*dims)))


# ---------------------------------------------------------------------------
# Plan construction
# ---------------------------------------------------------------------------
def make_plan(mesh: Mesh, cfg: ModelConfig, shape: ShapeSpec,
              overrides: Optional[dict[str, Any]] = None) -> ShardingPlan:
    names = mesh.axis_names
    data_axes = tuple(a for a in ("pod", "data") if a in names) or None
    model = "model" if "model" in names else None
    msize = mesh.shape[model] if model else 1

    batch1 = shape.global_batch == 1
    rules: dict[str, Any] = {
        "batch": None if batch1 else data_axes,
        # sequence-parallel residual stream for train; long-context decode
        # spreads the cache/sequence over the idle data axes instead
        "seq": (model if shape.kind == "train" else
                (data_axes if batch1 else None)),
        "emb": None,
        "w_emb": None,   # set to "data" for FSDP/ZeRO-3 weight sharding
        "q_dim": model, "kv_dim": model, "head_dim": None,
        "ff": model, "vocab": model,
        "sh_ff": model,
        "ssm_inner": model, "nh": model, "gn": None,
        "conv_k": None, "conv_c": model,
        "state": None,
        "seq_const": None,
        "experts_r": None,
    }
    # attention activation sharding: heads over 'model' when they divide
    # it; otherwise shard the QUERY SEQUENCE over 'model' for the S^2
    # score/context matmuls (context parallelism) — without this, a head
    # count like phi4-mini's 24 on a 16-way axis replicates the whole
    # attention computation on every device (16x flops).
    heads_ok = bool(cfg.n_heads) and model is not None \
        and cfg.n_heads % msize == 0
    kv_ok = bool(cfg.n_kv_heads) and model is not None \
        and cfg.n_kv_heads % msize == 0
    rules["q_heads"] = model if heads_ok else None
    rules["kv_heads_act"] = model if kv_ok else None
    rules["q_seq"] = (model if (not heads_ok and cfg.n_heads
                                and shape.kind in ("train", "prefill"))
                      else None)
    # experts: EP over model when it divides, else TP inside each expert
    if cfg.moe is not None and model is not None:
        if cfg.moe.n_experts % msize == 0:
            rules["experts"] = model
            rules["ff"] = None
        else:
            rules["experts"] = None
            rules["ff"] = model
    else:
        rules["experts"] = None
    # MLP hidden activations: ff-sharded (classic TP) when ff weights are
    # sharded; otherwise sequence-sharded (seq-local MLP, zero MLP
    # collectives — pairs with replicated MLP weights via {'ff': None}).
    rules["h_ff"] = rules["ff"]
    rules["h_seq"] = None if rules["ff"] is not None else rules["seq"]
    # KV-cache sharding (decode input cache / prefill output cache):
    # shard kv heads when they divide the model axis, else split the
    # cache sequence over it (flash-decode style).
    if shape.kind in ("decode", "prefill"):
        kv_shardable = (cfg.n_kv_heads and model is not None
                        and cfg.n_kv_heads % msize == 0)
        rules["cache_kv_heads"] = model if kv_shardable else None
        rules["cache_seq"] = ((data_axes if batch1 else None) if kv_shardable
                              else model)
    else:
        rules["cache_kv_heads"] = None
        rules["cache_seq"] = None
    if overrides:
        rules.update(overrides)
        if "ff" in overrides and "h_ff" not in overrides:
            rules["h_ff"] = rules["ff"]
            rules["h_seq"] = None if rules["ff"] is not None else rules["seq"]
    return ShardingPlan(mesh=mesh, rules=rules, cfg=cfg, shape=shape)


# ---------------------------------------------------------------------------
# Pytree spec derivation
# ---------------------------------------------------------------------------
def _leaf_name(path) -> str:
    for entry in reversed(path):
        if hasattr(entry, "key"):
            return str(entry.key)
    return ""


def param_specs(plan: ShardingPlan, params) -> Any:
    """NamedSharding tree matching ``params`` via LEAF_AXES."""
    def one(path, leaf):
        name = _leaf_name(path)
        axes = LEAF_AXES.get(name)
        if axes is None:
            raise KeyError(f"no LEAF_AXES entry for param {name!r} "
                           f"(path {jax.tree_util.keystr(path)})")
        stack = leaf.ndim - len(axes)
        assert stack >= 0, (name, leaf.shape, axes)
        logical = (None,) * stack + axes
        dims = []
        for d, lname in enumerate(logical):
            ax = plan.rules.get(lname) if lname else None
            if ax is not None and leaf.shape[d] % plan.axis_size(ax) != 0:
                ax = None
            dims.append(ax)
        return NamedSharding(plan.mesh, P(*dims))
    return jax.tree_util.tree_map_with_path(one, params)


def data_specs(plan: ShardingPlan, batch) -> Any:
    """NamedSharding tree for an input batch / cache pytree.

    Leaf logical axes are resolved by name convention:
      tokens/labels      (B, S)            -> (batch, None)
      vis_embeds/frames  (B, S, d)         -> (batch, None, None)
      k/v caches         (.., B, S, KV, d) -> (.., batch, cache_seq, kv, None)
      ssm state          (L, B, nh, p, n)  -> (None, batch, nh, None, None)
      conv state         (L, B, k-1, c)    -> (None, batch, None, conv_c)
      pos                (B,)              -> (batch,)
      memory             (B, S, d)         -> (batch, None, None)
    """
    def one(path, leaf):
        name = _leaf_name(path)
        nd = leaf.ndim
        if name in ("tokens", "labels", "loss_mask"):
            logical = ("batch",) + (None,) * (nd - 1)
        elif name in ("vis_embeds", "frames", "memory"):
            logical = ("batch", None, None)
        elif name in ("k", "v", "cross_k", "cross_v"):
            stack = nd - 4
            logical = (None,) * stack + ("batch", "cache_seq",
                                         "cache_kv_heads", None)
        elif name == "ssm":
            stack = nd - 4
            logical = (None,) * stack + ("batch", "nh", None, None)
        elif name == "conv":
            stack = nd - 3
            logical = (None,) * stack + ("batch", None, "conv_c")
        elif name == "pos":
            logical = ("batch",)
        else:
            logical = (None,) * nd
        dims = []
        for d, lname in enumerate(logical):
            ax = plan.rules.get(lname) if lname else None
            if ax is not None and leaf.shape[d] % plan.axis_size(ax) != 0:
                ax = None
            dims.append(ax)
        return NamedSharding(plan.mesh, P(*dims))
    return jax.tree_util.tree_map_with_path(one, batch)
