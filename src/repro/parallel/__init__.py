from .sharding import (ShardingPlan, activate, active_plan, data_specs,
                       make_plan, param_specs, shard)

__all__ = ["ShardingPlan", "activate", "active_plan", "data_specs",
           "make_plan", "param_specs", "shard"]
