"""Shared transformer layers: norms, positions, GQA attention, SwiGLU.

All functions are pure; parameters are dicts of arrays. Attention and
RMSNorm route through :mod:`repro.kernels.ops` (Pallas on TPU, jnp ref on
CPU). Activation shardings are constrained with logical axis names via
:func:`repro.parallel.shard`.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs import ModelConfig
from ..kernels import ops
from ..parallel import shard


# ---------------------------------------------------------------------------
# Init helpers
# ---------------------------------------------------------------------------
def dense_init(key, shape, dtype, in_axis: int = 0):
    fan_in = shape[in_axis] if in_axis is not None else shape[0]
    std = fan_in ** -0.5
    return (jax.random.truncated_normal(key, -3.0, 3.0, shape, jnp.float32)
            * std).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.truncated_normal(key, -3.0, 3.0, shape, jnp.float32)
            ).astype(dtype)


# ---------------------------------------------------------------------------
# Norms / positions
# ---------------------------------------------------------------------------
def rmsnorm(x, scale, eps: float = 1e-5):
    return ops.rmsnorm(x, scale, eps)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, D) with positions (S,) or (B, S)."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freq  # (B,S,half)
    sin, cos = jnp.sin(ang)[:, :, None, :], jnp.cos(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoid(positions: jax.Array, d: int) -> jax.Array:
    """Sinusoidal absolute embeddings: positions (S,)|(B,S) -> (..., d)."""
    half = d // 2
    freq = jnp.exp(-jnp.arange(half, dtype=jnp.float32)
                   * (jnp.log(10_000.0) / max(half - 1, 1)))
    ang = positions[..., None].astype(jnp.float32) * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# Attention (init + full-sequence + decode variants)
# ---------------------------------------------------------------------------
def attn_init(key, cfg: ModelConfig, dtype) -> dict:
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, qd), dtype),
        "wk": dense_init(ks[1], (d, kvd), dtype),
        "wv": dense_init(ks[2], (d, kvd), dtype),
        "wo": dense_init(ks[3], (qd, d), dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((cfg.resolved_head_dim,), dtype)
        p["k_norm"] = jnp.ones((cfg.resolved_head_dim,), dtype)
    return p


def _qkv(p, x, cfg: ModelConfig, positions):
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = (x @ p["wq"]).reshape(b, s, cfg.n_heads, hd)
    k = (x @ p["wk"]).reshape(b, s, cfg.n_kv_heads, hd)
    v = (x @ p["wv"]).reshape(b, s, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    if cfg.use_rope and positions is not None:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    q = shard(q, "batch", "q_seq", "q_heads", None)
    k = shard(k, "batch", None, "kv_heads_act", None)
    v = shard(v, "batch", None, "kv_heads_act", None)
    return q, k, v


def attn_forward(p, x, cfg: ModelConfig, *, causal: bool = True,
                 q_offset: int = 0, kv: tuple | None = None):
    """Full-sequence attention. Returns (out, (k, v)) for cache building.

    ``kv`` overrides computed k/v (cross-attention against memory).
    """
    b, s, _ = x.shape
    positions = q_offset + jnp.arange(s)
    q, k_new, v_new = _qkv(p, x, cfg, positions if cfg.use_rope else None)
    k, v = kv if kv is not None else (k_new, v_new)
    o = ops.attention(q, k, v, causal=causal, q_offset=q_offset)
    o = shard(o, "batch", "q_seq", "q_heads", None)
    out = o.reshape(b, s, cfg.q_dim) @ p["wo"]
    return out, (k_new, v_new)


def attn_decode(p, x, cache_k, cache_v, pos, cfg: ModelConfig, *,
                update_cache: bool = True):
    """One-token attention. x: (B, 1, d); caches: (B, S, KVH, hd); pos: (B,).

    Returns (out, new_k_cache, new_v_cache).
    """
    b = x.shape[0]
    positions = pos[:, None]  # (B,1)
    q, k_new, v_new = _qkv(p, x, cfg, positions if cfg.use_rope else None)
    if update_cache:
        bidx = jnp.arange(b)
        cache_k = cache_k.at[bidx, pos].set(k_new[:, 0])
        cache_v = cache_v.at[bidx, pos].set(v_new[:, 0])
    o = ops.decode_attention(q, cache_k, cache_v, pos)
    out = o.reshape(b, 1, cfg.q_dim) @ p["wo"]
    return out, cache_k, cache_v


def cross_attn_decode(p, x, mem_k, mem_v, cfg: ModelConfig):
    """Decoder cross-attention against precomputed memory k/v (full valid)."""
    b = x.shape[0]
    q = (x @ p["wq"]).reshape(b, 1, cfg.n_heads, cfg.resolved_head_dim)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
    s_mem = mem_k.shape[1]
    full = jnp.full((b,), s_mem - 1, jnp.int32)
    o = ops.decode_attention(q, mem_k, mem_v, full)
    return o.reshape(b, 1, cfg.q_dim) @ p["wo"]


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------
def mlp_init(key, cfg: ModelConfig, dtype, d_ff: int | None = None) -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(ks[0], (d, f), dtype),
        "w_up": dense_init(ks[1], (d, f), dtype),
        "w_down": dense_init(ks[2], (f, d), dtype),
    }


def mlp_forward(p, x):
    h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    h = shard(h, "batch", "h_seq", "h_ff")
    return h @ p["w_down"]


# ---------------------------------------------------------------------------
# Dense transformer block (pre-norm residual)
# ---------------------------------------------------------------------------
def dense_block_init(key, cfg: ModelConfig, dtype) -> dict:
    ka, km = jax.random.split(key)
    return {
        "attn": attn_init(ka, cfg, dtype),
        "mlp": mlp_init(km, cfg, dtype),
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "ln2": jnp.ones((cfg.d_model,), dtype),
    }


def dense_block(p, x, cfg: ModelConfig, *, causal: bool = True,
                q_offset: int = 0):
    h, kvs = attn_forward(p["attn"], rmsnorm(x, p["ln1"], cfg.norm_eps), cfg,
                          causal=causal, q_offset=q_offset)
    x = x + h
    x = x + mlp_forward(p["mlp"], rmsnorm(x, p["ln2"], cfg.norm_eps))
    x = shard(x, "batch", "seq", "emb")
    return x, kvs


def dense_block_decode(p, x, cache_k, cache_v, pos, cfg: ModelConfig):
    h, ck, cv = attn_decode(p["attn"], rmsnorm(x, p["ln1"], cfg.norm_eps),
                            cache_k, cache_v, pos, cfg)
    x = x + h
    x = x + mlp_forward(p["mlp"], rmsnorm(x, p["ln2"], cfg.norm_eps))
    return x, ck, cv
