"""Model builder: one ``build_model(cfg)`` for all six assigned families.

Every family exposes the same functional surface:

  init(key) -> params                                  (stacked per layer)
  loss_fn(params, batch) -> (loss, metrics)            (train step core)
  prefill(params, batch) -> (last_logits, cache)       (inference prefill)
  decode_step(params, cache, tokens, pos)
      -> (logits, new_cache)                           (one-token serve)
  init_cache(batch_size, cache_len) -> zeros cache

Layers are ALWAYS consumed via ``jax.lax.scan`` over stacked params so the
lowered HLO (and compile time on 512-way SPMD) is depth-independent.
Backward memory is controlled by ``remat`` ('full' | 'dots' | 'none').
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from ..configs import ModelConfig
from ..parallel import shard
from . import layers as L
from .moe import moe_forward, moe_init
from .ssm import mamba_block, mamba_decode, mamba_init

Pytree = Any


def _remat(fn, mode: str):
    if mode == "none":
        return fn
    if mode == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)  # 'full': save nothing


def _stack_init(key, n: int, init_one: Callable):
    return jax.vmap(init_one)(jax.random.split(key, n))


def _dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


@dataclasses.dataclass
class Model:
    cfg: ModelConfig
    init: Callable
    loss_fn: Callable       # (params, batch) -> (loss, metrics)
    prefill: Callable       # (params, batch) -> (logits, cache)
    decode_step: Callable   # (params, cache, tokens, pos) -> (logits, cache)
    init_cache: Callable    # (batch_size, cache_len) -> cache pytree


# ---------------------------------------------------------------------------
# Shared pieces
# ---------------------------------------------------------------------------
def _head_init(key, cfg: ModelConfig, dt):
    ke, kh = jax.random.split(key)
    p = {"embed": L.embed_init(ke, (cfg.vocab_size, cfg.d_model), dt),
         "final_norm": jnp.ones((cfg.d_model,), dt)}
    if not cfg.tie_embeddings:
        p["head"] = L.dense_init(kh, (cfg.d_model, cfg.vocab_size), dt)
    return p


def _logits(p, x, cfg: ModelConfig):
    x = L.rmsnorm(x, p["final_norm"], cfg.norm_eps)
    w = p["embed"].T if cfg.tie_embeddings else p["head"]
    logits = x @ w.astype(x.dtype)
    names = ("batch",) + (None,) * (logits.ndim - 2) + ("vocab",)
    return shard(logits, *names)


def _xent(logits, labels):
    """Mean token cross-entropy; logits (..., V) in any float dtype."""
    lg = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lg, axis=-1)
    gold = jnp.take_along_axis(lg, labels[..., None], axis=-1)[..., 0]
    return (lse - gold).mean()


def _xent_chunked(p, x, labels, cfg: ModelConfig, chunk: int):
    """CE with the head matmul + softmax streamed over seq chunks.

    Never materialises the full (B, S, V) logits — the win is large for
    200k-vocab heads (phi4-mini). Each chunk is rematerialised in the
    backward pass (jax.checkpoint), trading ~6*d*V chunk flops for
    O(B*S*V) activation bytes.
    """
    b, s, _ = x.shape
    n = s // chunk
    assert s % chunk == 0, (s, chunk)
    xc = x.reshape(b, n, chunk, -1)
    lc = labels.reshape(b, n, chunk)

    @jax.checkpoint
    def one(xi, li):
        return _xent(_logits(p, xi, cfg), li)

    def body(acc, inp):
        xi, li = inp
        return acc + one(xi, li), None

    total, _ = jax.lax.scan(
        body, jnp.float32(0.0),
        (jnp.moveaxis(xc, 1, 0), jnp.moveaxis(lc, 1, 0)))
    return total / n


def _loss_from_x(p, x, labels, cfg: ModelConfig, loss_chunk):
    if loss_chunk:
        return _xent_chunked(p, x, labels, cfg, loss_chunk)
    return _xent(_logits(p, x, cfg), labels)


def _embed_tokens(p, tokens):
    return shard(p["embed"][tokens], "batch", "seq", "emb")


# ---------------------------------------------------------------------------
# Decoder-only LM (dense / moe / vlm)
# ---------------------------------------------------------------------------
def _build_lm(cfg: ModelConfig, remat: str, loss_chunk=None) -> Model:
    dt = _dtype(cfg)
    is_moe = cfg.family == "moe"

    def block_init(k):
        p = {"attn": L.attn_init(k, cfg, dt),
             "ln1": jnp.ones((cfg.d_model,), dt),
             "ln2": jnp.ones((cfg.d_model,), dt)}
        km, kk = jax.random.split(jax.random.fold_in(k, 1))
        if is_moe:
            p["moe"] = moe_init(km, cfg, dt)
        else:
            p["mlp"] = L.mlp_init(km, cfg, dt)
        return p

    def init(key):
        kl, kh = jax.random.split(key)
        return {"layers": _stack_init(kl, cfg.n_layers, block_init),
                **_head_init(kh, cfg, dt)}

    def _ffn(pl, h):
        if is_moe:
            return moe_forward(pl["moe"], h, cfg)
        return L.mlp_forward(pl["mlp"], h), 0.0

    def _block_train(carry, pl):
        x, aux = carry
        h, _ = L.attn_forward(pl["attn"], L.rmsnorm(x, pl["ln1"], cfg.norm_eps),
                              cfg, causal=True)
        x = x + h
        f, a = _ffn(pl, L.rmsnorm(x, pl["ln2"], cfg.norm_eps))
        x = shard(x + f, "batch", "seq", "emb")
        return (x, aux + a), None

    def forward_train(p, x):
        (x, aux), _ = jax.lax.scan(_remat(_block_train, remat), (x, 0.0),
                                   p["layers"])
        return x, aux

    def loss_fn(p, batch):
        if cfg.family == "vlm":
            x = jnp.concatenate(
                [batch["vis_embeds"].astype(dt),
                 _embed_tokens(p, batch["tokens"])], axis=1)
        else:
            x = _embed_tokens(p, batch["tokens"])
        x, aux = forward_train(p, x)
        if cfg.family == "vlm":
            x = x[:, cfg.n_vis_tokens:]
        loss = _loss_from_x(p, x, batch["labels"], cfg, loss_chunk)
        total = loss + 0.01 * aux
        return total, {"xent": loss, "aux": aux}

    def _block_prefill(carry, pl):
        x, aux = carry
        h, (k, v) = L.attn_forward(pl["attn"],
                                   L.rmsnorm(x, pl["ln1"], cfg.norm_eps),
                                   cfg, causal=True)
        x = x + h
        f, a = _ffn(pl, L.rmsnorm(x, pl["ln2"], cfg.norm_eps))
        x = shard(x + f, "batch", "seq", "emb")
        return (x, aux + a), (k, v)

    def prefill(p, batch):
        if cfg.family == "vlm":
            x = jnp.concatenate(
                [batch["vis_embeds"].astype(dt),
                 _embed_tokens(p, batch["tokens"])], axis=1)
        else:
            x = _embed_tokens(p, batch["tokens"])
        b, s = x.shape[0], x.shape[1]
        (x, _), (ks, vs) = jax.lax.scan(_block_prefill, (x, 0.0), p["layers"])
        cache = {"k": shard(ks, None, "batch", "cache_seq", "cache_kv_heads", None),
                 "v": shard(vs, None, "batch", "cache_seq", "cache_kv_heads", None),
                 "pos": jnp.full((b,), s - 1, jnp.int32)}
        return _logits(p, x[:, -1], cfg), cache

    def _block_decode(carry, xs):
        x, pos = carry
        pl, ck, cv = xs
        x, ck, cv = L.dense_block_decode(pl, x, ck, cv, pos, cfg) \
            if not is_moe else _moe_block_decode(pl, x, ck, cv, pos)
        return (x, pos), (ck, cv)

    def _moe_block_decode(pl, x, ck, cv, pos):
        h, ck, cv = L.attn_decode(pl["attn"],
                                  L.rmsnorm(x, pl["ln1"], cfg.norm_eps),
                                  ck, cv, pos, cfg)
        x = x + h
        f, _ = moe_forward(pl["moe"], L.rmsnorm(x, pl["ln2"], cfg.norm_eps), cfg)
        return x + f, ck, cv

    def decode_step(p, cache, tokens, pos):
        x = _embed_tokens(p, tokens)  # (B, 1, d)
        (x, _), (ks, vs) = jax.lax.scan(
            _block_decode, (x, pos), (p["layers"], cache["k"], cache["v"]))
        new_cache = {"k": ks, "v": vs, "pos": pos}
        return _logits(p, x[:, -1], cfg), new_cache

    def init_cache(batch_size: int, cache_len: int):
        hd = cfg.resolved_head_dim
        shp = (cfg.n_layers, batch_size, cache_len, cfg.n_kv_heads, hd)
        return {"k": jnp.zeros(shp, dt), "v": jnp.zeros(shp, dt),
                "pos": jnp.zeros((batch_size,), jnp.int32)}

    return Model(cfg, init, loss_fn, prefill, decode_step, init_cache)


# ---------------------------------------------------------------------------
# Pure SSM (mamba2)
# ---------------------------------------------------------------------------
def _build_ssm(cfg: ModelConfig, remat: str, loss_chunk=None) -> Model:
    dt = _dtype(cfg)

    def init(key):
        kl, kh = jax.random.split(key)
        return {"layers": _stack_init(kl, cfg.n_layers,
                                      lambda k: mamba_init(k, cfg, dt)),
                **_head_init(kh, cfg, dt)}

    def _block(x, pl):
        y, _ = mamba_block(pl, x, cfg)
        return y, None

    def loss_fn(p, batch):
        x = _embed_tokens(p, batch["tokens"])
        x, _ = jax.lax.scan(_remat(_block, remat), x, p["layers"])
        loss = _loss_from_x(p, x, batch["labels"], cfg, loss_chunk)
        return loss, {"xent": loss, "aux": 0.0}

    def prefill(p, batch):
        x = _embed_tokens(p, batch["tokens"])
        b = x.shape[0]

        def body(x, pl):
            y, (conv, ssm) = mamba_block(pl, x, cfg, return_state=True)
            return y, (conv, ssm)

        x, (convs, ssms) = jax.lax.scan(body, x, p["layers"])
        cache = {"conv": convs, "ssm": ssms,
                 "pos": jnp.full((b,), batch["tokens"].shape[1] - 1, jnp.int32)}
        return _logits(p, x[:, -1], cfg), cache

    def decode_step(p, cache, tokens, pos):
        x = _embed_tokens(p, tokens)

        def body(x, xs):
            pl, conv, ssm = xs
            y, conv, ssm = mamba_decode(pl, x, conv, ssm, cfg)
            return y, (conv, ssm)

        x, (convs, ssms) = jax.lax.scan(
            body, x, (p["layers"], cache["conv"], cache["ssm"]))
        return (_logits(p, x[:, -1], cfg),
                {"conv": convs, "ssm": ssms, "pos": pos})

    def init_cache(batch_size: int, cache_len: int):
        s = cfg.ssm
        conv_c = cfg.d_inner + 2 * s.n_groups * s.state_dim
        return {
            "conv": jnp.zeros((cfg.n_layers, batch_size, s.conv_dim - 1,
                               conv_c), dt),
            "ssm": jnp.zeros((cfg.n_layers, batch_size, cfg.n_ssm_heads,
                              s.head_dim, s.state_dim), jnp.float32),
            "pos": jnp.zeros((batch_size,), jnp.int32),
        }

    return Model(cfg, init, loss_fn, prefill, decode_step, init_cache)


# ---------------------------------------------------------------------------
# Hybrid (zamba2): [5 mamba + shared attn] x 13  +  3 mamba
# ---------------------------------------------------------------------------
def _hybrid_layout(cfg: ModelConfig):
    k = cfg.attn_every
    n_super = cfg.n_layers // k            # superblocks of (k-1 mamba + attn)
    n_tail = cfg.n_layers - n_super * k    # trailing mamba layers
    return n_super, k - 1, n_tail


def _build_hybrid(cfg: ModelConfig, remat: str, loss_chunk=None) -> Model:
    dt = _dtype(cfg)
    n_super, m_per, n_tail = _hybrid_layout(cfg)

    def init(key):
        ka, kb, ksh, kh = jax.random.split(key, 4)
        mamba_a = _stack_init(ka, n_super * m_per,
                              lambda k: mamba_init(k, cfg, dt))
        mamba_a = jax.tree.map(
            lambda x: x.reshape(n_super, m_per, *x.shape[1:]), mamba_a)
        p = {"mamba_a": mamba_a,
             "shared_attn": L.dense_block_init(ksh, cfg, dt),
             **_head_init(kh, cfg, dt)}
        if n_tail:
            p["mamba_b"] = _stack_init(kb, n_tail,
                                       lambda k: mamba_init(k, cfg, dt))
        return p

    def _super_train(shared):
        def body(x, pl):
            def inner(xc, pm):
                y, _ = mamba_block(pm, xc, cfg)
                return y, None
            x, _ = jax.lax.scan(inner, x, pl)
            x, _ = L.dense_block(shared, x, cfg, causal=True)
            return x, None
        return body

    def _tail_train(x, pl):
        y, _ = mamba_block(pl, x, cfg)
        return y, None

    def loss_fn(p, batch):
        x = _embed_tokens(p, batch["tokens"])
        x, _ = jax.lax.scan(_remat(_super_train(p["shared_attn"]), remat),
                            x, p["mamba_a"])
        if n_tail:
            x, _ = jax.lax.scan(_remat(_tail_train, remat), x, p["mamba_b"])
        loss = _loss_from_x(p, x, batch["labels"], cfg, loss_chunk)
        return loss, {"xent": loss, "aux": 0.0}

    def prefill(p, batch):
        x = _embed_tokens(p, batch["tokens"])
        b, s = x.shape[0], x.shape[1]

        def body(x, pl):
            def inner(xc, pm):
                y, st = mamba_block(pm, xc, cfg, return_state=True)
                return y, st
            x, (conv, ssm) = jax.lax.scan(inner, x, pl)
            h, (k, v) = L.attn_forward(
                p["shared_attn"]["attn"],
                L.rmsnorm(x, p["shared_attn"]["ln1"], cfg.norm_eps), cfg)
            x = x + h
            x = x + L.mlp_forward(p["shared_attn"]["mlp"],
                                  L.rmsnorm(x, p["shared_attn"]["ln2"],
                                            cfg.norm_eps))
            return x, (conv, ssm, k, v)

        x, (conv_a, ssm_a, ks, vs) = jax.lax.scan(body, x, p["mamba_a"])
        cache = {"conv_a": conv_a, "ssm_a": ssm_a,
                 "k": shard(ks, None, "batch", "cache_seq", "cache_kv_heads", None),
                 "v": shard(vs, None, "batch", "cache_seq", "cache_kv_heads", None),
                 "pos": jnp.full((b,), s - 1, jnp.int32)}
        if n_tail:
            def tail(x, pl):
                y, st = mamba_block(pl, x, cfg, return_state=True)
                return y, st
            x, (conv_b, ssm_b) = jax.lax.scan(tail, x, p["mamba_b"])
            cache["conv_b"], cache["ssm_b"] = conv_b, ssm_b
        return _logits(p, x[:, -1], cfg), cache

    def decode_step(p, cache, tokens, pos):
        x = _embed_tokens(p, tokens)

        def body(x, xs):
            pl, conv, ssm, ck, cv = xs
            def inner(carry, xs_in):
                pm, c, s_ = xs_in
                y, c, s_ = mamba_decode(pm, carry, c, s_, cfg)
                return y, (c, s_)
            x, (conv, ssm) = jax.lax.scan(inner, x, (pl, conv, ssm))
            sa = p["shared_attn"]
            h, ck, cv = L.attn_decode(sa["attn"],
                                      L.rmsnorm(x, sa["ln1"], cfg.norm_eps),
                                      ck, cv, pos, cfg)
            x = x + h
            x = x + L.mlp_forward(sa["mlp"], L.rmsnorm(x, sa["ln2"],
                                                       cfg.norm_eps))
            return x, (conv, ssm, ck, cv)

        x, (conv_a, ssm_a, ks, vs) = jax.lax.scan(
            body, x, (p["mamba_a"], cache["conv_a"], cache["ssm_a"],
                      cache["k"], cache["v"]))
        new = {"conv_a": conv_a, "ssm_a": ssm_a, "k": ks, "v": vs, "pos": pos}
        if n_tail:
            def tail(x, xs_in):
                pm, c, s_ = xs_in
                y, c, s_ = mamba_decode(pm, x, c, s_, cfg)
                return y, (c, s_)
            x, (conv_b, ssm_b) = jax.lax.scan(
                tail, x, (p["mamba_b"], cache["conv_b"], cache["ssm_b"]))
            new["conv_b"], new["ssm_b"] = conv_b, ssm_b
        return _logits(p, x[:, -1], cfg), new

    def init_cache(batch_size: int, cache_len: int):
        s = cfg.ssm
        conv_c = cfg.d_inner + 2 * s.n_groups * s.state_dim
        hd = cfg.resolved_head_dim
        cache = {
            "conv_a": jnp.zeros((n_super, m_per, batch_size, s.conv_dim - 1,
                                 conv_c), dt),
            "ssm_a": jnp.zeros((n_super, m_per, batch_size, cfg.n_ssm_heads,
                                s.head_dim, s.state_dim), jnp.float32),
            "k": jnp.zeros((n_super, batch_size, cache_len, cfg.n_kv_heads,
                            hd), dt),
            "v": jnp.zeros((n_super, batch_size, cache_len, cfg.n_kv_heads,
                            hd), dt),
            "pos": jnp.zeros((batch_size,), jnp.int32),
        }
        if n_tail:
            cache["conv_b"] = jnp.zeros((n_tail, batch_size, s.conv_dim - 1,
                                         conv_c), dt)
            cache["ssm_b"] = jnp.zeros((n_tail, batch_size, cfg.n_ssm_heads,
                                        s.head_dim, s.state_dim), jnp.float32)
        return cache

    return Model(cfg, init, loss_fn, prefill, decode_step, init_cache)


# ---------------------------------------------------------------------------
# Encoder-decoder (whisper) — frames arrive pre-embedded (stub frontend)
# ---------------------------------------------------------------------------
def _build_enc_dec(cfg: ModelConfig, remat: str, loss_chunk=None) -> Model:
    dt = _dtype(cfg)

    def dec_block_init(k):
        ks, kc, km = jax.random.split(k, 3)
        return {"self": L.attn_init(ks, cfg, dt),
                "cross": L.attn_init(kc, cfg, dt),
                "mlp": L.mlp_init(km, cfg, dt),
                "ln1": jnp.ones((cfg.d_model,), dt),
                "ln2": jnp.ones((cfg.d_model,), dt),
                "ln3": jnp.ones((cfg.d_model,), dt)}

    def init(key):
        ke, kd, kh = jax.random.split(key, 3)
        return {
            "enc_layers": _stack_init(ke, cfg.n_enc_layers,
                                      lambda k: L.dense_block_init(k, cfg, dt)),
            "dec_layers": _stack_init(kd, cfg.n_layers, dec_block_init),
            **_head_init(kh, cfg, dt),
        }

    def encode(p, frames):
        x = frames.astype(dt) + L.sinusoid(
            jnp.arange(frames.shape[1]), cfg.d_model).astype(dt)

        def body(x, pl):
            y, _ = L.dense_block(pl, x, cfg, causal=False)
            return y, None

        x, _ = jax.lax.scan(_remat(body, remat), x, p["enc_layers"])
        return shard(x, "batch", None, "emb")

    def _dec_block(memory):
        def body(x, pl):
            h, kv = L.attn_forward(pl["self"],
                                   L.rmsnorm(x, pl["ln1"], cfg.norm_eps),
                                   cfg, causal=True)
            x = x + h
            cross_kv = _mem_kv(pl["cross"], memory)  # cache the MEMORY k/v
            hc, _ = L.attn_forward(
                pl["cross"], L.rmsnorm(x, pl["ln2"], cfg.norm_eps), cfg,
                causal=False, kv=cross_kv)
            x = x + hc
            x = x + L.mlp_forward(pl["mlp"],
                                  L.rmsnorm(x, pl["ln3"], cfg.norm_eps))
            return x, (kv, cross_kv)
        return body

    def _mem_kv(pc, memory):
        b, s, _ = memory.shape
        hd = cfg.resolved_head_dim
        k = (memory @ pc["wk"]).reshape(b, s, cfg.n_kv_heads, hd)
        v = (memory @ pc["wv"]).reshape(b, s, cfg.n_kv_heads, hd)
        return k, v

    def _dec_embed(p, tokens):
        x = _embed_tokens(p, tokens)
        return x + L.sinusoid(jnp.arange(tokens.shape[1]),
                              cfg.d_model).astype(dt)

    def loss_fn(p, batch):
        memory = encode(p, batch["frames"])
        x = _dec_embed(p, batch["tokens"])
        x, _ = jax.lax.scan(_remat(_dec_block(memory), remat), x,
                            p["dec_layers"])
        loss = _loss_from_x(p, x, batch["labels"], cfg, loss_chunk)
        return loss, {"xent": loss, "aux": 0.0}

    def prefill(p, batch):
        memory = encode(p, batch["frames"])
        x = _dec_embed(p, batch["tokens"])
        b, s = x.shape[0], x.shape[1]
        x, (kvs, cross_kvs) = jax.lax.scan(_dec_block(memory), x,
                                           p["dec_layers"])
        (ks, vs), (cks, cvs) = kvs, cross_kvs
        cache = {"k": ks, "v": vs, "cross_k": cks, "cross_v": cvs,
                 "pos": jnp.full((b,), s - 1, jnp.int32)}
        return _logits(p, x[:, -1], cfg), cache

    def decode_step(p, cache, tokens, pos):
        x = _embed_tokens(p, tokens)
        x = x + L.sinusoid(pos[:, None], cfg.d_model).astype(dt)

        def body(carry, xs):
            x, pos = carry
            pl, ck, cv, mk, mv = xs
            h, ck, cv = L.attn_decode(pl["self"],
                                      L.rmsnorm(x, pl["ln1"], cfg.norm_eps),
                                      ck, cv, pos, cfg)
            x = x + h
            x = x + L.cross_attn_decode(pl["cross"],
                                        L.rmsnorm(x, pl["ln2"], cfg.norm_eps),
                                        mk, mv, cfg)
            x = x + L.mlp_forward(pl["mlp"],
                                  L.rmsnorm(x, pl["ln3"], cfg.norm_eps))
            return (x, pos), (ck, cv)

        (x, _), (ks, vs) = jax.lax.scan(
            body, (x, pos), (p["dec_layers"], cache["k"], cache["v"],
                             cache["cross_k"], cache["cross_v"]))
        new = dict(cache, k=ks, v=vs, pos=pos)
        return _logits(p, x[:, -1], cfg), new

    def init_cache(batch_size: int, cache_len: int):
        hd = cfg.resolved_head_dim
        enc_len = max(cache_len // 4, 1)
        self_shp = (cfg.n_layers, batch_size, cache_len, cfg.n_kv_heads, hd)
        cross_shp = (cfg.n_layers, batch_size, enc_len, cfg.n_kv_heads, hd)
        return {"k": jnp.zeros(self_shp, dt), "v": jnp.zeros(self_shp, dt),
                "cross_k": jnp.zeros(cross_shp, dt),
                "cross_v": jnp.zeros(cross_shp, dt),
                "pos": jnp.zeros((batch_size,), jnp.int32)}

    return Model(cfg, init, loss_fn, prefill, decode_step, init_cache)


# ---------------------------------------------------------------------------
# Entry point
# ---------------------------------------------------------------------------
def build_model(cfg: ModelConfig, remat: str = "full",
                loss_chunk: Optional[int] = None) -> Model:
    if cfg.family in ("dense", "moe", "vlm"):
        return _build_lm(cfg, remat, loss_chunk)
    if cfg.family == "ssm":
        return _build_ssm(cfg, remat, loss_chunk)
    if cfg.family == "hybrid":
        return _build_hybrid(cfg, remat, loss_chunk)
    if cfg.family == "enc_dec":
        return _build_enc_dec(cfg, remat, loss_chunk)
    raise ValueError(f"unknown family {cfg.family!r}")
