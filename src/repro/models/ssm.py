"""Mamba2 block: projections + causal conv + gated SSD scan.

Forward uses the chunked SSD scan (ops.ssd_scan — Pallas on TPU); decode
uses the O(1) recurrence with conv/state caches. The block follows
arXiv:2405.21060: x/z/B/C/dt projections, depthwise conv over the (x,B,C)
streams, per-head scalar decay A, gated RMSNorm before out-projection.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs import ModelConfig
from ..kernels import ops
from ..parallel import shard
from .layers import dense_init, rmsnorm


def mamba_init(key, cfg: ModelConfig, dtype) -> dict:
    d, di, nh = cfg.d_model, cfg.d_inner, cfg.n_ssm_heads
    s = cfg.ssm
    gn = s.n_groups * s.state_dim
    conv_c = di + 2 * gn
    ks = jax.random.split(key, 7)
    return {
        "wz": dense_init(ks[0], (d, di), dtype),
        "wx": dense_init(ks[1], (d, di), dtype),
        "wB": dense_init(ks[2], (d, gn), dtype),
        "wC": dense_init(ks[3], (d, gn), dtype),
        "wdt": dense_init(ks[4], (d, nh), dtype),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh, dtype=jnp.float32)),
        "D": jnp.ones((nh,), jnp.float32),
        "conv_w": dense_init(ks[5], (s.conv_dim, conv_c), dtype, in_axis=0),
        "norm": jnp.ones((di,), dtype),
        "ln1": jnp.ones((d,), dtype),
        "out_proj": dense_init(ks[6], (di, d), dtype),
    }


def _project(p, x, cfg: ModelConfig):
    z = x @ p["wz"]
    xin = x @ p["wx"]
    Bc = x @ p["wB"]
    Cc = x @ p["wC"]
    dt = jax.nn.softplus(
        (x @ p["wdt"]).astype(jnp.float32) + p["dt_bias"])
    return z, xin, Bc, Cc, dt


def mamba_block(p, x, cfg: ModelConfig, *, return_state: bool = False):
    """x: (B, S, d) -> (y, (conv_cache, ssm_state)) if return_state."""
    s = cfg.ssm
    b, sl, d = x.shape
    di, nh = cfg.d_inner, cfg.n_ssm_heads
    xn = rmsnorm(x, p["ln1"], cfg.norm_eps)
    z, xin, Bc, Cc, dt = _project(p, xn, cfg)

    stream = jnp.concatenate([xin, Bc, Cc], axis=-1)
    stream = shard(stream, "batch", None, "conv_c")
    conv, conv_cache = ops.causal_conv1d(stream, p["conv_w"])
    conv = jax.nn.silu(conv)
    xin = conv[..., :di]
    Bc = conv[..., di:di + s.n_groups * s.state_dim]
    Cc = conv[..., di + s.n_groups * s.state_dim:]

    xh = xin.reshape(b, sl, nh, s.head_dim)
    xh = shard(xh, "batch", None, "nh", None)
    Bh = Bc.reshape(b, sl, s.n_groups, s.state_dim)
    Ch = Cc.reshape(b, sl, s.n_groups, s.state_dim)
    A = -jnp.exp(p["A_log"])
    # pad to a chunk multiple; padded steps are identity updates (dt = 0
    # -> decay exp(0) = 1, input contribution 0), so y[:sl] and the final
    # state are exact.
    pad = (-sl) % s.chunk
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Bh = jnp.pad(Bh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Ch = jnp.pad(Ch, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    y, state = ops.ssd_scan(xh, dt, A, Bh, Ch, p["D"], chunk=s.chunk)
    if pad:
        y = y[:, :sl]
    y = y.reshape(b, sl, di) * jax.nn.silu(z)
    y = rmsnorm(y, p["norm"], cfg.norm_eps)
    out = x + y @ p["out_proj"]
    out = shard(out, "batch", "seq", "emb")
    if return_state:
        return out, (conv_cache, state)
    return out, None


def mamba_decode(p, x, conv_cache, ssm_state, cfg: ModelConfig):
    """One token. x: (B, 1, d); conv_cache: (B, k-1, c); ssm_state: (B,nh,p,n).

    Returns (y, conv_cache, ssm_state).
    """
    s = cfg.ssm
    b = x.shape[0]
    di, nh = cfg.d_inner, cfg.n_ssm_heads
    xn = rmsnorm(x[:, 0], p["ln1"], cfg.norm_eps)
    z, xin, Bc, Cc, dt = _project(p, xn, cfg)

    stream = jnp.concatenate([xin, Bc, Cc], axis=-1)         # (B, c)
    conv, conv_cache = ops.conv1d_step(stream, p["conv_w"], conv_cache)
    conv = jax.nn.silu(conv)
    xin = conv[..., :di]
    Bc = conv[..., di:di + s.n_groups * s.state_dim]
    Cc = conv[..., di + s.n_groups * s.state_dim:]

    xh = xin.reshape(b, nh, s.head_dim)
    Bh = Bc.reshape(b, s.n_groups, s.state_dim)
    Ch = Cc.reshape(b, s.n_groups, s.state_dim)
    A = -jnp.exp(p["A_log"])
    y, ssm_state = ops.ssd_decode_step(ssm_state, xh, dt, A, Bh, Ch, p["D"])
    y = y.reshape(b, di) * jax.nn.silu(z)
    y = rmsnorm(y, p["norm"], cfg.norm_eps)
    out = x + (y @ p["out_proj"])[:, None, :]
    return out, conv_cache, ssm_state
