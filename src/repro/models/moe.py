"""Mixture-of-experts block: top-k routing, capacity dispatch, manual EP.

Dispatch is GShard-style GROUP-WISE (one group per sequence, per-group
expert capacity, overflow dropped): tokens are ranked within their expert
by a cumulative count, gathered into (E, C, d) buffers, pushed through
the expert SwiGLUs as one batched einsum, and combined back weighted by
router probs. Shared experts (qwen2-moe) run densely on every token.

Distribution: two code paths with IDENTICAL numerics —

* **pure path** (no active ShardingPlan; smoke tests, single device):
  plain jnp over (G, T, d).
* **manual-EP path** (active plan): the block runs under ``shard_map``.
  Tokens are sharded over the data axes and replicated over 'model', so
  each device dispatches its local groups, computes ONLY its expert slice
  (experts sharded over 'model' when E divides it — phi3.5 — otherwise
  the per-expert ff dim is sharded — qwen2-moe), writes the slice into
  the group-local combine buffer with a dynamic_update_slice, gathers
  per-token results locally, and a single ``psum`` over 'model' merges
  expert (or ff-partial) contributions. No GSPMD scatter decisions —
  the gather/scatter that made the partitioner replicate 34 GB buffers
  is now device-local by construction.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs import ModelConfig
from ..parallel import active_plan
from .layers import dense_init, mlp_forward, mlp_init

try:  # jax >= 0.6 exposes shard_map at top level
    shard_map = jax.shard_map
except AttributeError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map


def moe_init(key, cfg: ModelConfig, dtype) -> dict:
    m = cfg.moe
    d, f, e = cfg.d_model, cfg.d_ff, m.n_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, e), jnp.float32),
        "moe_gate": dense_init(ks[1], (e, d, f), dtype, in_axis=1),
        "moe_up": dense_init(ks[2], (e, d, f), dtype, in_axis=1),
        "moe_down": dense_init(ks[3], (e, f, d), dtype, in_axis=1),
    }
    if m.n_shared_experts:
        sf = m.shared_d_ff * m.n_shared_experts
        sh = mlp_init(ks[4], cfg, dtype, d_ff=sf)
        p.update({"sh_gate": sh["w_gate"], "sh_up": sh["w_up"],
                  "sh_down": sh["w_down"]})
    return p


# ---------------------------------------------------------------------------
# Core (device-local or single-device) dispatch + expert compute + combine
# ---------------------------------------------------------------------------
def _dispatch(x, router, cfg: ModelConfig):
    """Per-group top-k routing. x: (g, t, d) -> (dest, weights, cap)."""
    m = cfg.moe
    g, t, _ = x.shape
    e, k = m.n_experts, m.top_k
    logits = x.astype(jnp.float32) @ router                  # (g, t, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                   # (g, t, k)
    top_p = top_p / jnp.clip(top_p.sum(-1, keepdims=True), 1e-9)

    cap = int(max(k * t * m.capacity_factor // e, 1))
    ef = top_e.reshape(g, t * k)
    onehot = jax.nn.one_hot(ef, e, dtype=jnp.int32)
    pos_in_e = jnp.cumsum(onehot, axis=1) - onehot
    rank = jnp.take_along_axis(pos_in_e, ef[..., None], axis=2)[..., 0]
    keep = rank < cap
    dest = jnp.where(keep, ef * cap + rank, e * cap)         # (g, t*k)
    w = (top_p * keep.reshape(g, t, k))                      # (g, t, k)
    return dest, w, cap


def _dispatch_buffers(x, dest, cap: int, e: int, k: int):
    """Scatter routed token copies into (g, e, cap, d) expert buffers."""
    g, t, d = x.shape
    gid = jnp.arange(g)[:, None]
    xk = jnp.repeat(x, k, axis=1)                            # (g, t*k, d)
    buf = jnp.zeros((g, e * cap + 1, d), x.dtype)
    buf = buf.at[gid, dest].set(xk)
    return buf[:, :-1].reshape(g, e, cap, d)


def _expert_mlp(xe, p):
    """Batched SwiGLU over (g, e_n, cap, d) with local weight slices."""
    h = (jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, p["moe_gate"]))
         * jnp.einsum("gecd,edf->gecf", xe, p["moe_up"]))
    return jnp.einsum("gecf,efd->gecd", h, p["moe_down"])


def _combine(ye_full, dest, weights, cap: int):
    """(g, e, cap, d) expert outputs -> per-token weighted sum."""
    g, e, _, d = ye_full.shape
    t, k = weights.shape[1], weights.shape[2]
    yflat = jnp.concatenate(
        [ye_full.reshape(g, e * cap, d).astype(jnp.float32),
         jnp.zeros((g, 1, d), jnp.float32)], axis=1)
    yk = jnp.take_along_axis(yflat, dest[..., None], axis=1)  # dropped -> 0
    yk = yk.reshape(g, t, k, d)
    return jnp.einsum("gtkd,gtk->gtd", yk, weights.astype(jnp.float32))


def _expert_core(x, p, cfg: ModelConfig, e_lo: int, e_n: int, cap: int,
                 dest, weights):
    """Dispatch -> local experts [e_lo, e_lo+e_n) -> combine (psum path).

    Weights p['moe_*'] hold only the local expert slice (e_n experts,
    possibly ff-partial). Returns the (partial) output (g, t, d).
    """
    m = cfg.moe
    g, t, d = x.shape
    e = m.n_experts
    xe = jax.lax.dynamic_slice_in_dim(
        _dispatch_buffers(x, dest, cap, e, m.top_k), e_lo, e_n, axis=1)
    ye = _expert_mlp(xe, p)                                  # (g, e_n, cap, d)
    yfull = jnp.zeros((g, e * cap, d), ye.dtype)
    yfull = jax.lax.dynamic_update_slice_in_dim(
        yfull, ye.reshape(g, e_n * cap, d), e_lo * cap, axis=1)
    return _combine(yfull.reshape(g, e, cap, d), dest, weights, cap)


def _aux_loss(x, router, cfg: ModelConfig):
    """Switch load-balance loss: E * sum_e f_e * P_e (plain jnp, global)."""
    m = cfg.moe
    e, k = m.n_experts, m.top_k
    logits = x.astype(jnp.float32) @ router
    probs = jax.nn.softmax(logits, axis=-1)
    _, top_e = jax.lax.top_k(probs, k)
    pe = probs.mean(axis=(0, 1))
    fe = jnp.zeros((e,), jnp.float32).at[top_e.reshape(-1)].add(
        1.0) / (top_e.size)
    return e * jnp.sum(fe * pe)


# ---------------------------------------------------------------------------
# Public block
# ---------------------------------------------------------------------------
def moe_forward(p, x, cfg: ModelConfig):
    """x: (G, T, d) -> (y, aux_loss). Groups = sequences (data-sharded)."""
    m = cfg.moe
    plan = active_plan()
    aux = _aux_loss(x, p["router"], cfg)

    routed = {k: p[k] for k in ("moe_gate", "moe_up", "moe_down")}
    if plan is None or plan.rules.get("batch") is None:
        dest, w, cap = _dispatch(x, p["router"], cfg)
        y = _expert_core(x, routed, cfg, 0, m.n_experts, cap, dest, w)
    else:
        y = _moe_shard_map(routed, p["router"], x, cfg, plan)

    y = y.astype(x.dtype)
    if m.n_shared_experts:
        y = y + mlp_forward({"w_gate": p["sh_gate"], "w_up": p["sh_up"],
                             "w_down": p["sh_down"]}, x)
    return y, aux


def _moe_shard_map(routed, router, x, cfg: ModelConfig, plan):
    """Manual expert parallelism. Two exchange schedules:

    * **psum path** (tokens replicated over 'model'): every device
      dispatches the full sequence, computes its expert slice, one psum
      merges. Simple, but the psum carries the FULL residual stream.
    * **all-to-all path** (tokens sequence-sharded over 'model' — the
      sequence-parallel prefill/train plans): each device dispatches its
      sequence slice into (g, E, C, d) buffers; ``lax.all_to_all`` over
      'model' exchanges expert shards (the paper's All-to-All pattern,
      GShard-style); only ROUTED TOKEN BUFFERS cross the wire —
      ~(k*cf/msize) of the psum path's bytes.
    """
    m = cfg.moe
    batch_axes = plan.rules["batch"]
    e_axis = plan.rules.get("experts")          # 'model' or None
    f_axis = plan.rules.get("ff")               # 'model' or None
    model_axis = e_axis or f_axis
    msize = plan.axis_size(model_axis) if model_axis else 1
    e = m.n_experts
    e_n = e // plan.axis_size(e_axis) if e_axis else e
    seq_ax = plan.rules.get("seq")
    a2a = bool(e_axis) and seq_ax == model_axis and msize > 1 \
        and x.shape[1] % msize == 0

    w_specs = {"moe_gate": P(e_axis, None, f_axis),
               "moe_up": P(e_axis, None, f_axis),
               "moe_down": P(e_axis, f_axis, None)}
    x_spec = P(batch_axes, model_axis if a2a else None, None)

    def local(weights, router_l, x_l):
        e_lo = jax.lax.axis_index(e_axis) * e_n if e_axis else 0
        dest, w, cap = _dispatch(x_l, router_l, cfg)
        if a2a:
            xe = _dispatch_buffers(x_l, dest, cap, e, m.top_k)
            # exchange expert shards: (g, E, C, d) -> (g, E/m, m*C, d)
            xr = jax.lax.all_to_all(xe, model_axis, split_axis=1,
                                    concat_axis=2, tiled=True)
            ye = _expert_mlp(xr, weights).astype(x_l.dtype)
            back = jax.lax.all_to_all(ye, model_axis, split_axis=2,
                                      concat_axis=1, tiled=True)
            return _combine(back, dest, w, cap).astype(x_l.dtype)
        y = _expert_core(x_l, weights, cfg, e_lo, e_n, cap, dest, w)
        if model_axis:
            y = jax.lax.psum(y.astype(x_l.dtype), model_axis)
        return y

    fn = shard_map(local, mesh=plan.mesh,
                   in_specs=(w_specs, P(None, None), x_spec),
                   out_specs=x_spec, check_vma=False)
    return fn(routed, router, x)
